#!/usr/bin/env python
"""Benchmark-baseline gate: every gated section ships a committed baseline.

Discovers the gated benchmark sections by scanning ``benchmarks/*.py`` for
literal ``write_json("<section>", ...)`` calls (the marker that a section
persists a machine-readable payload and participates in CI gating), then
requires a committed, schema-valid ``BENCH_<section>.json`` at the repo
root for each:

  * the file exists and parses as JSON;
  * the payload is a full-mode run (``"smoke": false``) -- CI smoke runs
    write throwaway grids and must not be committed as baselines;
  * the section's required keys are present (see ``REQUIRED_KEYS``), so a
    half-written or hand-edited baseline fails loudly;
  * the baseline is not *stale*: its last git commit must not predate the
    last commit touching the benchmark script that writes it (a gate whose
    thresholds or grid changed needs its baseline regenerated -- the
    failure message prints the exact regenerate command).  Skipped when
    either file is untracked or git history is unavailable (shallow
    clones: the CI checkout uses ``fetch-depth: 0`` so it is not).

A section added to ``benchmarks/`` with a ``write_json`` call and no
committed baseline fails this gate -- that is the point.  Wired into the
CI fast-tests job next to ``tools/check_docs.py``.  Run from anywhere::

    python tools/check_bench.py
"""

from __future__ import annotations

import json
import re
import subprocess
import sys
from pathlib import Path
from typing import Optional

ROOT = Path(__file__).resolve().parent.parent

#: Minimum key set per section, sized to what each payload actually
#: writes.  New sections need an entry here (the gate tells you so) --
#: deliberate, so a baseline's schema is reviewed once, in this file.
REQUIRED_KEYS = {
    "sweep": {"smoke", "snapshots", "architectures", "numpy_s", "scalar_s",
              "jax_s", "devices", "telemetry"},
    "churn": {"smoke", "traces", "architectures", "num_nodes", "scalar_s",
              "numpy_s", "bit_exact", "telemetry"},
    "dcn": {"smoke", "num_nodes", "samples", "fault_ratios", "scalar_s",
            "numpy_s", "bit_exact_vs_scalar_rows", "curve_orchestrated",
            "near_zero_frontier", "telemetry"},
    "cost": {"smoke", "samples", "fault_ratios", "architectures",
             "table6_per_gpu_usd", "headline_ratios", "fig17d_musd_tp32",
             "bit_exact_vs_scalar_rows", "telemetry"},
    "matrix": {"smoke", "num_nodes", "architectures", "fault_ratios",
               "backends", "bit_exact_backends", "rows", "telemetry"},
    "scale": {"smoke", "snapshots", "num_nodes", "architectures", "backends",
              "gate_floors_snaps_per_sec", "numpy_snaps_per_sec",
              "overlap_snapshots", "stream_equal", "full_snaps_per_sec",
              "peak_rss_mb", "churn_stream_equal", "runtime", "telemetry"},
    "serve": {"smoke", "num_nodes", "intervals", "architectures",
              "arrival_streams", "requests_total", "scalar_s", "numpy_s",
              "bit_exact", "slo_table", "goodput_retention_ok", "telemetry"},
    "faults": {"smoke", "num_nodes", "samples", "architectures",
               "generators", "scalar_s", "numpy_s", "bit_exact",
               "scenario_table", "claim_breaks", "telemetry"},
}

#: Shape of the ``telemetry`` block ``benchmarks.common.write_json`` stamps
#: (``repro.obs.Telemetry.summary()``): top-level sections plus the per-span
#: aggregate fields.
TELEMETRY_KEYS = {"enabled", "spans", "counters", "gauges"}
TELEMETRY_SPAN_KEYS = {"count", "total_s", "self_s"}


def check_telemetry(section: str, payload: dict) -> list:
    """Validate the payload's telemetry block: summary shape, span rows,
    and that a full-mode run actually collected spans (an empty block
    means pin_runtime()'s enable was bypassed)."""
    problems = []
    tel = payload.get("telemetry")
    if not isinstance(tel, dict):
        return [f"{section}: telemetry block missing or not an object"]
    missing = sorted(TELEMETRY_KEYS - set(tel))
    if missing:
        return [f"{section}: telemetry block is missing {missing}"]
    if tel.get("enabled") is not True:
        problems.append(
            f"{section}: telemetry.enabled={tel.get('enabled')!r}; "
            f"baseline runs must collect telemetry (pin_runtime enables it)")
    spans = tel.get("spans")
    if not isinstance(spans, dict) or not spans:
        problems.append(
            f"{section}: telemetry.spans is empty -- the engines' "
            f"instrumentation did not run")
        return problems
    for name, row in spans.items():
        if not isinstance(row, dict) \
                or not TELEMETRY_SPAN_KEYS <= set(row):
            problems.append(
                f"{section}: telemetry.spans[{name!r}] must carry "
                f"{sorted(TELEMETRY_SPAN_KEYS)}")
            break
    return problems

WRITE_JSON_RE = re.compile(r"""write_json\(\s*["']([A-Za-z0-9_]+)["']""")


def _commit_time(relpath: str) -> Optional[int]:
    """Unix time of the last commit touching ``relpath``; None when the
    file is untracked or git history is unavailable."""
    try:
        out = subprocess.run(
            ["git", "log", "-1", "--format=%ct", "--", relpath],
            capture_output=True, text=True, cwd=ROOT, timeout=30)
    except (OSError, subprocess.TimeoutExpired):
        return None
    if out.returncode != 0:
        return None
    stamp = out.stdout.strip()
    return int(stamp) if stamp.isdigit() else None


def gated_sections() -> dict:
    """Map section name -> defining benchmark file, from literal
    ``write_json("name", ...)`` calls.  (``roofline`` takes ``write_json``
    as a bool flag and persists under ``results/`` -- no literal call, so
    it is correctly not picked up.)"""
    sections = {}
    for path in sorted((ROOT / "benchmarks").glob("*.py")):
        for m in WRITE_JSON_RE.finditer(path.read_text()):
            sections[m.group(1)] = path.name
    return sections


def check_section(section: str, source: str) -> list:
    problems = []
    path = ROOT / f"BENCH_{section}.json"
    if not path.exists():
        problems.append(
            f"{section}: benchmarks/{source} persists BENCH_{section}.json "
            f"but no baseline is committed at the repo root -- run "
            f"`PYTHONPATH=src python -m benchmarks.{section}` and commit it")
        return problems
    try:
        payload = json.loads(path.read_text())
    except json.JSONDecodeError as e:
        problems.append(f"{section}: {path.name} is not valid JSON ({e})")
        return problems
    if not isinstance(payload, dict) or not payload:
        problems.append(f"{section}: {path.name} must be a non-empty object")
        return problems
    if payload.get("smoke") is not False:
        problems.append(
            f"{section}: {path.name} has smoke={payload.get('smoke')!r}; "
            f"committed baselines must be full-mode runs (smoke: false)")
    required = REQUIRED_KEYS.get(section)
    if required is None:
        problems.append(
            f"{section}: new gated section -- add its required-key schema "
            f"to REQUIRED_KEYS in tools/check_bench.py")
    else:
        missing = sorted(required - set(payload))
        if missing:
            problems.append(
                f"{section}: {path.name} is missing required keys: "
                f"{missing}")
        elif "telemetry" in required:
            problems.extend(check_telemetry(section, payload))
    # staleness: a baseline committed before the benchmark script's last
    # change was measured against a different gate/grid
    baseline_ct = _commit_time(path.name)
    script_ct = _commit_time(f"benchmarks/{source}")
    if baseline_ct is not None and script_ct is not None \
            and baseline_ct < script_ct:
        problems.append(
            f"{section}: {path.name} (committed {script_ct - baseline_ct}s "
            f"earlier) predates the last change to benchmarks/{source} -- "
            f"regenerate with `PYTHONPATH=src python -m benchmarks."
            f"{source[:-3]}` and commit the new {path.name}")
    return problems


def main() -> int:
    sections = gated_sections()
    if not sections:
        print("no gated sections found under benchmarks/ -- "
              "is the checkout complete?")
        return 1
    problems = []
    for section in sorted(sections):
        problems.extend(check_section(section, sections[section]))
    # Inverse direction: a committed baseline whose section no longer
    # exists is stale and misleads readers about what CI verifies.
    for path in sorted(ROOT.glob("BENCH_*.json")):
        section = path.stem[len("BENCH_"):]
        if section not in sections:
            problems.append(
                f"{section}: {path.name} is committed but no benchmarks/*.py "
                f"writes it -- delete the stale baseline")
    if problems:
        print("benchmark baseline violations:")
        for p in problems:
            print(f"  {p}")
        return 1
    print(f"benchmark baselines OK ({len(sections)} gated sections, "
          f"all with committed full-mode schema-valid baselines: "
          f"{', '.join(sorted(sections))})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
