#!/usr/bin/env python
"""Summarize a ``repro.obs`` Chrome-trace JSON file.

Reads the Perfetto-loadable trace that ``REPRO_TRACE=1`` (or
``repro.obs.export``) produces and prints three views:

  * **top spans by self-time** -- per span name: call count, total wall
    time, and self time (duration minus child spans), recomputed from the
    trace's event nesting (same ts/dur containment a Perfetto flame chart
    shows) so the report validates the file's structure rather than
    trusting the embedded ``self_us`` args;
  * **counter totals** -- final cumulative value of every counter track;
  * **rate timeline** -- for one counter (default
    ``sim.snapshots_evaluated``), per-bucket deltas as an events/sec
    timeline, e.g. snapshots/sec over the run.

Importable for tests: :func:`load_trace`, :func:`span_summary`,
:func:`counter_totals`, :func:`rate_timeline`.  Run from anywhere::

    python tools/trace_report.py repro.trace.json
    python tools/trace_report.py repro.trace.json --top 30 \\
        --rate prng.masks_generated --buckets 20
"""

from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict
from typing import Dict, List, Optional, Tuple


def load_trace(path: str) -> dict:
    """Load a Chrome-trace JSON file; validates the basic envelope."""
    with open(path) as f:
        trace = json.load(f)
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        raise ValueError(f"{path}: not a Chrome-trace JSON object "
                         "(no traceEvents)")
    return trace


def _complete_events(trace: dict) -> List[dict]:
    return [e for e in trace["traceEvents"] if e.get("ph") == "X"]


def span_summary(trace: dict) -> Dict[str, Dict[str, float]]:
    """Per span name: ``{count, total_us, self_us}``, self-time recomputed
    from ts/dur nesting per thread (children subtract from the innermost
    enclosing span, exactly the live collector's accounting)."""
    by_tid: Dict[Tuple, List[dict]] = defaultdict(list)
    for e in _complete_events(trace):
        by_tid[(e.get("pid"), e.get("tid"))].append(e)
    agg: Dict[str, Dict[str, float]] = {}
    for events in by_tid.values():
        # sort by start asc, then duration desc: parents precede children
        events.sort(key=lambda e: (e["ts"], -e["dur"]))
        stack: List[dict] = []          # open spans, innermost last
        for e in events:
            end = e["ts"] + e["dur"]
            while stack and e["ts"] >= stack[-1]["_end"] - 1e-9:
                stack.pop()
            if stack:
                stack[-1]["_child"] += e["dur"]
            e["_end"], e["_child"] = end, 0.0
            stack.append(e)
        for e in events:
            row = agg.setdefault(e["name"],
                                 {"count": 0, "total_us": 0.0,
                                  "self_us": 0.0})
            row["count"] += 1
            row["total_us"] += e["dur"]
            row["self_us"] += e["dur"] - e.pop("_child")
            e.pop("_end", None)
    return agg


def counter_totals(trace: dict) -> Dict[str, float]:
    """Final cumulative value per counter track (``ph:"C"``,
    ``cat:"counter"``)."""
    latest: Dict[str, Tuple[float, float]] = {}
    for e in trace["traceEvents"]:
        if e.get("ph") != "C" or e.get("cat") != "counter":
            continue
        value = next(iter(e.get("args", {}).values()), 0.0)
        ts = e.get("ts", 0.0)
        if e["name"] not in latest or ts >= latest[e["name"]][0]:
            latest[e["name"]] = (ts, value)
    return {name: v for name, (_, v) in sorted(latest.items())}


def rate_timeline(trace: dict, counter: str,
                  buckets: int = 10) -> List[Tuple[float, float]]:
    """``(bucket_end_ms, events_per_sec)`` rows for one cumulative counter.

    Buckets span first..last sample; each bucket's rate is the cumulative
    delta across it divided by the bucket width -- e.g. snapshots/sec over
    the run for ``sim.snapshots_evaluated``.
    """
    samples = [(e["ts"], next(iter(e["args"].values())))
               for e in trace["traceEvents"]
               if e.get("ph") == "C" and e.get("name") == counter]
    if len(samples) < 2:
        return []
    samples.sort()
    t0, t1 = samples[0][0], samples[-1][0]
    width = max((t1 - t0) / buckets, 1e-9)
    rows = []
    prev_v = samples[0][1]
    si = 1
    for b in range(1, buckets + 1):
        edge = t0 + b * width
        v = prev_v
        while si < len(samples) and samples[si][0] <= edge + 1e-9:
            v = samples[si][1]
            si += 1
        rows.append((edge / 1e3, (v - prev_v) / (width / 1e6)))
        prev_v = v
    return rows


def format_report(trace: dict, top: int = 20,
                  rate_counter: Optional[str] = None,
                  buckets: int = 10) -> str:
    lines: List[str] = []
    spans = span_summary(trace)
    lines.append(f"{'span':<36} {'count':>7} {'total_ms':>10} "
                 f"{'self_ms':>10}")
    ranked = sorted(spans.items(), key=lambda kv: -kv[1]["self_us"])
    for name, row in ranked[:top]:
        lines.append(f"{name:<36} {row['count']:>7d} "
                     f"{row['total_us'] / 1e3:>10.3f} "
                     f"{row['self_us'] / 1e3:>10.3f}")
    totals = counter_totals(trace)
    if totals:
        lines.append("")
        lines.append(f"{'counter':<44} {'total':>12}")
        for name, v in totals.items():
            lines.append(f"{name:<44} {v:>12g}")
    if rate_counter:
        rows = rate_timeline(trace, rate_counter, buckets)
        lines.append("")
        if rows:
            lines.append(f"{rate_counter} rate timeline")
            lines.append(f"{'t_ms':>12} {'per_sec':>14}")
            for t_ms, rate in rows:
                lines.append(f"{t_ms:>12.3f} {rate:>14.1f}")
        else:
            lines.append(f"{rate_counter}: <2 samples, no timeline")
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Summarize a repro.obs Chrome-trace JSON file")
    ap.add_argument("trace", help="trace file (REPRO_TRACE=1 output)")
    ap.add_argument("--top", type=int, default=20,
                    help="span rows to print (by self time)")
    ap.add_argument("--rate", default="sim.snapshots_evaluated",
                    help="counter to render as a rate timeline "
                         "('' disables)")
    ap.add_argument("--buckets", type=int, default=10,
                    help="rate-timeline bucket count")
    args = ap.parse_args(argv)
    try:
        trace = load_trace(args.trace)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"trace_report: {e}", file=sys.stderr)
        return 1
    try:
        print(format_report(trace, top=args.top,
                            rate_counter=args.rate or None,
                            buckets=args.buckets))
    except BrokenPipeError:   # `trace_report ... | head` closed the pipe
        sys.stderr.close()    # suppress the interpreter's epilogue warning
    return 0


if __name__ == "__main__":
    sys.exit(main())
