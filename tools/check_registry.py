#!/usr/bin/env python
"""Registry-completeness gate: no architecture lands half-wired.

Walks every :class:`repro.core.arch.ArchSpec` in the registry (builtins
plus the ``repro.archs`` rival zoo) and fails if any architecture is
missing a piece of the contract:

  * a *scalar reference* -- the factory's model must override
    ``evaluate()``;
  * a *batched kernel* -- the model must override ``_batch_eval()``, and
    a seeded probe grid must match the scalar path bit-for-bit;
  * a *BOM entry or explicit unpriceable marker* -- exactly one of
    ``ArchSpec.bom`` / ``ArchSpec.unpriceable`` (with matching BOM name);
  * a *placement hook* the DCN engine implements (``placement_variant``
    in ``repro.dcn.VARIANTS``, or ``None``);
  * a *device kernel path* when JAX is installed
    (``repro.sim.jax_backend.available_for``);
  * a *test exercising it* -- some file under ``tests/`` must quote the
    architecture name (``"railx"`` or ``'railx'``).

Wired into the CI fast-tests job next to ``tools/check_docs.py``.  Run
from anywhere::

    python tools/check_registry.py
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

import numpy as np

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

PROBE_NODES = 96
PROBE_TPS = (8, 24, 32, 64)
PROBE_SNAPSHOTS = 8
PROBE_RATIO = 0.12


def tested_names() -> set:
    """Architecture names quoted anywhere under tests/."""
    quoted = set()
    for path in sorted((ROOT / "tests").glob("*.py")):
        for m in re.finditer(r"""["']([A-Za-z0-9_.-]+)["']""",
                             path.read_text()):
            quoted.add(m.group(1))
    return quoted


def check_spec(spec, quoted: set) -> list:
    from repro.core.hbd_models import HBDModel
    from repro.dcn.engine import VARIANTS
    problems = []

    def bad(what: str) -> None:
        problems.append((spec.name, what))

    model = spec.factory(PROBE_NODES, 4)
    if model.name != spec.name:
        bad(f"factory builds a model named {model.name!r}")
    if type(model).evaluate is HBDModel.evaluate:
        bad("missing scalar reference: model does not override evaluate()")
    if type(model)._batch_eval is HBDModel._batch_eval:
        bad("missing batched kernel: model does not override _batch_eval()")
    else:
        rng = np.random.default_rng(0)
        masks = rng.random((PROBE_SNAPSHOTS, PROBE_NODES)) < PROBE_RATIO
        grid = model.evaluate_batch(masks, PROBE_TPS)
        for si in range(PROBE_SNAPSHOTS):
            faults = set(np.nonzero(masks[si])[0].tolist())
            for ti, tp in enumerate(PROBE_TPS):
                ref = model.evaluate(faults, tp)
                got = grid.result(si, ti)
                if (got.total_gpus, got.faulty_gpus, got.placed_gpus) != \
                        (ref.total_gpus, ref.faulty_gpus, ref.placed_gpus):
                    bad(f"batched kernel != scalar reference at "
                        f"snapshot {si}, TP {tp}")
                    break
            else:
                continue
            break

    if (spec.bom is None) == (spec.unpriceable is None):
        bad("must set exactly one of bom= and unpriceable=")
    elif spec.bom is not None and spec.bom.name != spec.name:
        bad(f"BOM is named {spec.bom.name!r}")

    if spec.placement_variant is not None \
            and spec.placement_variant not in VARIANTS:
        bad(f"placement_variant {spec.placement_variant!r} not implemented "
            f"by repro.dcn (known: {VARIANTS})")

    from repro.sim import jax_backend
    if jax_backend.HAVE_JAX and not jax_backend.available_for([model]):
        bad("no device kernel: neither a builtin jax_backend kernel nor "
            "ArchSpec.jax_kernel")

    if spec.name not in quoted:
        bad("no test exercises it (no tests/*.py quotes the name)")
    return problems


def main() -> int:
    from repro.core import arch
    specs = arch.specs()
    quoted = tested_names()
    problems = []
    for spec in specs:
        problems.extend(check_spec(spec, quoted))
    if problems:
        print("registry contract violations:")
        for name, what in problems:
            print(f"  {name}: {what}")
        print()
        print(arch.registration_help())
        return 1
    priced = sum(1 for s in specs if s.bom is not None)
    print(f"registry OK ({len(specs)} architectures checked: scalar+batched "
          f"bit-exact, {priced} priced / {len(specs) - priced} explicitly "
          f"unpriceable, all named in tests)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
